"""N-D ``Domain`` API tests: the 2-D ``GridGeom`` shim parity, per-axis
boundary validation, the 3-D spatial stack (sweep parity, scan-fused
equivalence, one-pass migration across all three axes, re-shard identity),
the ``init_refs`` proto-slab contract, and the sharded delta closed-loop
reference invariant (incl. across a mid-run re-shard).

Sharded cases run in subprocesses (XLA placeholder devices must be set
before jax initializes), mirroring tests/test_distributed_abm.py.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AgentSchema, AgentSoA, Behavior, DeltaConfig, Domain, Engine, GridGeom,
    POS, Simulation, compose, total_agents,
)
from repro.core.behaviors import displacement_update, soft_repulsion_adhesion
from repro.core.domain import normalize_boundary, spatial_axis_names
from repro.core.halo import dirs_for, init_refs, take_slab
from repro.core.neighbors import (
    offsets_for, resolve_sweep_backend, sweep_accumulate,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 2, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


MECH_SCHEMA = AgentSchema.create({
    "diameter": ((), jnp.float32),
    "ctype": ((), jnp.int32),
})


def mech_behavior(**over):
    params = {"repulsion": 2.0, "adhesion": 0.4, "same_type_only": 1.0,
              "max_step": 0.5}
    params.update(over)
    return Behavior(
        schema=MECH_SCHEMA, pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
        radius=2.0, params=params)


def mech_inputs(dom: Domain, n=150, seed=0):
    rng = np.random.default_rng(seed)
    size = dom.domain_size
    pos = rng.uniform([0.5] * dom.ndim,
                      [s - 0.5 for s in size], (n, dom.ndim)
                      ).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, n).astype(np.int32)}
    return pos, attrs


def live_positions(state):
    pos = np.asarray(state.soa.attrs[POS])
    pos = pos.reshape(-1, pos.shape[-1])
    v = np.asarray(state.soa.valid).ravel()
    return pos[v]


def live_gids(state):
    v = np.asarray(state.soa.valid).ravel()
    gr = np.asarray(state.soa.attrs["gid_rank"]).ravel()[v]
    gc = np.asarray(state.soa.attrs["gid_count"]).ravel()[v]
    return gr.astype(np.int64) * (1 << 32) + gc


# ---------------------------------------------------------------------------
# Domain construction + validation (satellite: boundary values)
# ---------------------------------------------------------------------------

def test_domain_normalization_and_derived_geometry():
    d = Domain(cell_size=2.0, interior=(4, 6, 8), cap=16)
    assert d.ndim == 3
    assert d.mesh_shape == (1, 1, 1)        # all-ones default broadcasts
    assert d.boundary == ("closed",) * 3    # string broadcasts per axis
    assert d.local_shape == (6, 8, 10)
    assert d.global_cells == (4, 6, 8)
    assert d.domain_size == (8.0, 12.0, 16.0)
    assert d.toroidal == (False, False, False)
    assert hash(d) == hash(Domain(cell_size=2.0, interior=(4, 6, 8),
                                  cap=16))
    d2 = d.with_mesh_shape((2, 1, 2))
    assert d2.interior == (2, 6, 4) and d2.global_cells == d.global_cells
    assert d2.n_devices == 4


def test_domain_rejects_unknown_boundary_values():
    # historically any string was silently treated as "closed"; now it is
    # a construction-time error, per axis or broadcast
    with pytest.raises(ValueError, match="unknown boundary"):
        Domain(cell_size=2.0, interior=(4, 4), boundary="open")
    with pytest.raises(ValueError, match="unknown boundary"):
        Domain(cell_size=2.0, interior=(4, 4),
               boundary=("closed", "periodic"))
    with pytest.raises(ValueError, match="entries for a"):
        Domain(cell_size=2.0, interior=(4, 4),
               boundary=("closed", "toroidal", "closed"))
    with pytest.raises(ValueError, match="unknown boundary"):
        normalize_boundary("reflective", 2)
    # per-axis mixes are legal
    d = Domain(cell_size=2.0, interior=(4, 4, 4),
               boundary=("toroidal", "closed", "toroidal"))
    assert d.toroidal == (True, False, True)


def test_domain_shape_validation():
    with pytest.raises(ValueError, match="2-D and 3-D"):
        Domain(cell_size=2.0, interior=(4,))
    with pytest.raises(ValueError, match="axes for a"):
        Domain(cell_size=2.0, interior=(4, 4, 4), mesh_shape=(2, 1))
    with pytest.raises(ValueError, match="does not divide"):
        Domain(cell_size=2.0, interior=(4, 4)).with_mesh_shape((3, 1))


def test_gridgeom_shim_warns_and_returns_equal_domain():
    with pytest.warns(DeprecationWarning, match="GridGeom is deprecated"):
        g = GridGeom(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1),
                     cap=24, boundary="toroidal")
    d = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(1, 1), cap=24,
               boundary="toroidal")
    assert isinstance(g, Domain)
    assert g == d and hash(g) == hash(d)


def test_axis_helpers():
    assert spatial_axis_names(3) == ("sx", "sy", "sz")
    assert dirs_for(3)["zp"] == (2, +1)
    assert len(offsets_for(3)) == 27 and offsets_for(3)[13] == (0, 0, 0)


# ---------------------------------------------------------------------------
# Satellite: init_refs proto-slab selection (the former dead expression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("interior", [(3, 5), (3, 4, 5)])
def test_init_refs_proto_slabs_match_per_axis_face_shapes(interior):
    """Every directed edge's reference slab must have the shape of a face
    taken along that edge's OWN axis (an anisotropic grid catches any
    axis-0-only proto selection, which the former
    ``0 if axis == 0 else 0`` expression silently hid)."""
    dom = Domain(cell_size=2.0, interior=interior, cap=4)
    soa = AgentSoA.empty(MECH_SCHEMA, dom.local_shape, dom.cap)
    refs = init_refs(dom, soa)
    assert set(refs) == {d + sfx for d in dirs_for(dom.ndim)
                         for sfx in ("_out", "_in")}
    for d, (axis, _) in dirs_for(dom.ndim).items():
        face = take_slab(soa, axis, 0)
        for key in (d + "_out", d + "_in"):
            for field, v in refs[key].items():
                assert v.shape == face[field].shape, (key, field)
                assert not np.asarray(v).any()      # zero-valued
    # anisotropy check: axis-0 and axis-1 refs really differ in shape
    assert refs["xp_out"]["valid"].shape != refs["yp_out"]["valid"].shape


# ---------------------------------------------------------------------------
# (a) 2-D parity: existing sims bit-exact through the GridGeom shim
# ---------------------------------------------------------------------------

def _sim_cases():
    from repro.sims import (cell_clustering, cell_proliferation,
                            epidemiology, oncology)
    return {
        "cell_clustering": (cell_clustering, "closed"),
        "cell_proliferation": (cell_proliferation, "closed"),
        "epidemiology": (epidemiology, "toroidal"),
        "oncology": (oncology, "closed"),
    }


@pytest.mark.parametrize("name", sorted(_sim_cases()))
def test_2d_sims_bit_exact_through_gridgeom_shim(name):
    mod, boundary = _sim_cases()[name]
    kwargs = dict(cell_size=2.0, interior=(6, 6), mesh_shape=(1, 1),
                  cap=32, boundary=boundary)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        geom_shim = GridGeom(**kwargs)
    geom_dom = Domain(**kwargs)

    def final(geom):
        sim = Simulation(geom, mod.behavior(), dt=0.1)
        if name == "epidemiology":
            mod.init(sim, 80, 8, seed=3)
        else:
            mod.init(sim, 80, seed=3)
        sim.run(5)
        return sim.state

    s1 = final(geom_shim)
    s2 = final(geom_dom)
    np.testing.assert_array_equal(np.asarray(s1.soa.valid),
                                  np.asarray(s2.soa.valid))
    for k in s1.soa.attrs:
        np.testing.assert_array_equal(np.asarray(s1.soa.attrs[k]),
                                      np.asarray(s2.soa.attrs[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(s1.key), np.asarray(s2.key))


# ---------------------------------------------------------------------------
# (b) 3-D sweep parity + scan-fused equivalence
# ---------------------------------------------------------------------------

def _state_3d(beh, boundary="closed", n=120, interior=(4, 4, 4), cap=16,
              seed=0):
    dom = Domain(cell_size=2.0, interior=interior, cap=cap,
                 boundary=boundary)
    eng = Engine(geom=dom, behavior=beh, dt=0.1)
    rng = np.random.default_rng(seed)
    size = dom.domain_size
    pos = rng.uniform([0.5] * 3, [s - 0.5 for s in size], (n, 3)
                      ).astype(np.float32)
    attrs = {}
    for name, _, dtype in beh.schema.fields:
        if dtype == jnp.int32:
            attrs[name] = rng.integers(0, 2, n).astype(np.int32)
        else:
            attrs[name] = rng.uniform(0.6, 1.4, n).astype(np.float32)
    return eng, eng.init_state(pos, attrs, seed=seed)


@pytest.mark.parametrize("boundary", ["closed", "toroidal"])
def test_3d_sweep_tiled_matches_reference(boundary):
    from repro.sims import tumor_spheroid

    beh = tumor_spheroid.behavior()    # composed stack, count accumulator
    eng, state = _state_3d(beh, boundary)

    def sweep(backend):
        fn = jax.jit(lambda soa: sweep_accumulate(
            eng.geom, soa, beh.pair_fn, beh.pair_attrs, beh.radius,
            beh.params, backend=backend))
        return fn(state.soa)

    want = sweep("reference")
    got = sweep("tiled")
    assert set(got) == set(want)
    assert any(k.endswith("crowd") for k in want)
    for k in want:
        g, w = np.asarray(got[k]), np.asarray(want[k])
        if k.endswith("crowd"):     # pure neighbor counts: exact
            np.testing.assert_array_equal(g, w, err_msg=k)
        else:
            np.testing.assert_allclose(g, w, atol=1e-5, rtol=1e-5,
                                       err_msg=k)


def test_3d_pallas_backend_resolution():
    # the kernel factory takes 3-D blocks since the uneven-ownership PR:
    # auto resolves identically in 2-D and 3-D (pallas on TPU, tiled
    # elsewhere) and explicit backends pass through unchanged
    assert resolve_sweep_backend("reference", ndim=3) == "reference"
    assert resolve_sweep_backend("pallas", ndim=3) == "pallas"
    if jax.default_backend() != "tpu":
        assert resolve_sweep_backend("auto", ndim=2) == "tiled"
        assert resolve_sweep_backend("auto", ndim=3) == "tiled"


@pytest.mark.parametrize("delta", [False, True])
def test_3d_scan_fused_matches_per_step(delta):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        beh = mech_behavior()
        cfg = DeltaConfig(enabled=delta, qdtype=jnp.int16,
                          refresh_interval=4)
        dom = Domain(cell_size=2.0, interior=(4, 4, 4), cap=16)
        eng = Engine(geom=dom, behavior=beh, delta_cfg=cfg, dt=0.1)
        pos, attrs = mech_inputs(dom, n=130)
        s0 = eng.init_state(pos, attrs, seed=0)

        _, s1, _ = eng.drive(s0, 9, step_fn=eng.make_local_step())
        _, s2, _ = eng.drive(s0, 9)        # scan-fused segments

        np.testing.assert_array_equal(np.asarray(s1.soa.attrs[POS]),
                                      np.asarray(s2.soa.attrs[POS]))
        np.testing.assert_array_equal(np.asarray(s1.soa.valid),
                                      np.asarray(s2.soa.valid))
        assert int(s2.it.ravel()[0]) == 9


# ---------------------------------------------------------------------------
# (c) 3-D migration invariants
# ---------------------------------------------------------------------------

def _drift_behavior(vel):
    def drift(attrs, valid, acc, key, params, dt):
        new = dict(attrs)
        new[POS] = attrs[POS] + jnp.where(
            valid[..., None], jnp.asarray(vel, jnp.float32), 0.0)
        return new, valid, jnp.zeros_like(valid), None

    return Behavior(
        schema=MECH_SCHEMA, pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"), update_fn=drift, radius=2.0,
        params={"repulsion": 0.0, "adhesion": 0.0, "same_type_only": 0.0,
                "max_step": 0.0})


def test_3d_one_pass_migration_through_triple_diagonal_wrap():
    """Toroidal 3-D domain with a drift crossing a cell ring on ALL three
    axes every step: every agent exercises the triple-corner forwarding
    path (x-ring -> widened y payload -> widened z payload) each
    iteration; population, ids, bounds and drop counters must hold."""
    beh = _drift_behavior((1.7, 1.3, 1.9))
    dom = Domain(cell_size=2.0, interior=(4, 4, 4), cap=16,
                 boundary="toroidal")
    eng = Engine(geom=dom, behavior=beh, dt=1.0)
    rng = np.random.default_rng(1)
    n = 150
    size = dom.domain_size
    pos = rng.uniform(0.0, size[0], (n, 3)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": np.zeros((n,), np.int32)}
    state = eng.init_state(pos, attrs, seed=0)
    _, state, _ = eng.drive(state, 20)
    assert total_agents(state) == n
    assert int(state.dropped.sum()) == 0
    p = live_positions(state)
    for a in range(3):
        assert (p[:, a] >= 0).all() and (p[:, a] <= size[a]).all()
    assert len(np.unique(live_gids(state))) == n


def test_3d_mixed_per_axis_boundaries():
    """toroidal x/z wrap while the closed y axis clamps — per-axis
    boundary conditions through migration and the update clip."""
    # cap sized for the pile-up on the closed axis's far wall (every
    # drifting agent ends on the y = L - eps plane)
    beh = _drift_behavior((1.7, 0.9, -1.3))
    dom = Domain(cell_size=2.0, interior=(4, 4, 4), cap=48,
                 boundary=("toroidal", "closed", "toroidal"))
    eng = Engine(geom=dom, behavior=beh, dt=1.0)
    pos, attrs = mech_inputs(dom, n=120, seed=2)
    state = eng.init_state(pos, attrs, seed=0)
    _, state, _ = eng.drive(state, 15)
    assert total_agents(state) == 120
    assert int(state.dropped.sum()) == 0
    p = live_positions(state)
    size = dom.domain_size
    for a in range(3):
        assert (p[:, a] >= 0).all() and (p[:, a] <= size[a]).all()
    # the drifting closed axis piles up at the far wall; toroidal axes
    # keep wrapping (no pile-up at either wall)
    assert p[:, 1].max() > size[1] - 0.1


# ---------------------------------------------------------------------------
# (d) 3-D sharded: oracle parity, re-shard identity, elastic restore
# ---------------------------------------------------------------------------

COMMON_3D = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, DeltaConfig, Domain, Engine, total_agents
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 200
pos = rng.uniform([0.5]*3, [7.5, 7.5, 15.5], size=(n, 3)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, size=(n,)).astype(np.int32)}

def sorted_positions(state):
    v = np.asarray(state.soa.valid).ravel()
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 3)[v]
    return p[np.lexsort(p.T)]

def gids(state):
    v = np.asarray(state.soa.valid).ravel()
    gr = np.asarray(state.soa.attrs["gid_rank"]).ravel()[v]
    gc = np.asarray(state.soa.attrs["gid_count"]).ravel()[v]
    return np.sort(gr.astype(np.int64) * (1 << 32) + gc)
"""


def test_3d_sharded_matches_single_device_oracle_with_delta():
    out = run_sub(COMMON_3D + """
from repro.launch.mesh import make_abm_mesh
cfg = DeltaConfig(enabled=True, qdtype=jnp.int16, refresh_interval=4)

geom1 = Domain(cell_size=2.0, interior=(4, 4, 8), cap=16)
eng1 = Engine(geom=geom1, behavior=beh, delta_cfg=cfg, dt=0.1)
s1 = eng1.init_state(pos, attrs, seed=0)
_, s1, _ = eng1.drive(s1, 8)

geom2 = Domain(cell_size=2.0, interior=(4, 4, 4), mesh_shape=(1, 1, 2), cap=16)
eng2 = Engine(geom=geom2, behavior=beh, delta_cfg=cfg, dt=0.1)
s2 = eng2.init_state(pos, attrs, seed=0)
_, s2, _ = eng2.drive(s2, 8, mesh=make_abm_mesh((1, 1, 2)))

assert total_agents(s2) == n, "agent loss"
assert int(s2.halo_bytes.ravel()[0]) > 0
err = np.max(np.abs(sorted_positions(s1) - sorted_positions(s2)))
assert err < 0.05, f"divergence {err}"   # delta-quantization drift bound
print("OK", err)
""")
    assert "OK" in out


def test_3d_reshard_preserves_gids_iteration_and_rng_lineage():
    out = run_sub(COMMON_3D + """
from repro.core.reshard import reshard_state
from repro.launch.mesh import make_abm_mesh

geom = Domain(cell_size=2.0, interior=(4, 4, 4), mesh_shape=(1, 1, 2), cap=16)
eng = Engine(geom=geom, behavior=beh, dt=0.1)
state = eng.init_state(pos, attrs, seed=0)
step = eng.make_sharded_step(make_abm_mesh((1, 1, 2)))
for _ in range(4):
    state = step(state, full_halo=True)
g0, p0 = gids(state), sorted_positions(state)
key0 = np.asarray(state.key)[0, 0, 0]

eng2, state2 = reshard_state(eng, state, (2, 1, 1))
assert eng2.geom.mesh_shape == (2, 1, 1)
assert eng2.geom.interior == (2, 4, 8)
assert eng2.geom.global_cells == geom.global_cells
assert int(state2.it.ravel()[0]) == 4                  # iteration preserved
np.testing.assert_array_equal(gids(state2), g0)        # ids preserved
np.testing.assert_allclose(sorted_positions(state2), p0, atol=1e-6)
# RNG lineage: per-device keys re-split from fold_in(base_key, it), so the
# same (base_key, it) pair maps to the same key set regardless of mesh
want = jax.random.split(jax.random.fold_in(jnp.asarray(key0, jnp.uint32), 4), 2)
np.testing.assert_array_equal(
    np.asarray(state2.key).reshape(2, -1), np.asarray(want))
# the re-sharded engine steps on
step2 = eng2.make_sharded_step(make_abm_mesh((2, 1, 1)))
state2 = step2(state2, full_halo=True)
assert total_agents(state2) == n
print("OK")
""")
    assert "OK" in out


def test_3d_elastic_restore_roundtrips_per_axis_boundary(tmp_path):
    out = run_sub(COMMON_3D + f"""
from repro.distributed.checkpoint import save_abm
from repro.distributed.elastic import elastic_restore_abm
from repro.launch.mesh import make_abm_mesh

geom = Domain(cell_size=2.0, interior=(4, 4, 4), mesh_shape=(1, 1, 2),
              cap=16, boundary=("toroidal", "closed", "toroidal"))
eng = Engine(geom=geom, behavior=beh, dt=0.1)
state = eng.init_state(pos, attrs, seed=0)
step = eng.make_sharded_step(make_abm_mesh((1, 1, 2)))
for _ in range(3):
    state = step(state, full_halo=True)
path = save_abm({str(tmp_path)!r}, 3, eng, state)
eng2, state2, step_ = elastic_restore_abm({str(tmp_path)!r}, beh, n_devices=2)
assert eng2.geom.ndim == 3
assert eng2.geom.boundary == ("toroidal", "closed", "toroidal")
assert eng2.geom.global_cells == geom.global_cells
assert total_agents(state2) == n
np.testing.assert_array_equal(gids(state2), gids(state))
print("OK", eng2.geom.mesh_shape)
""")
    assert "OK" in out


# ---------------------------------------------------------------------------
# Satellite: sharded delta closed-loop reference invariant
# ---------------------------------------------------------------------------

def test_delta_refs_closed_loop_invariant_sharded_and_across_reshard():
    """My ``xp_out`` reference must equal my +x neighbor's ``xm_in`` after
    ANY mix of full refreshes and quantized-delta steps — and again after
    a mid-run re-shard reset the references (the refs-reset path forces
    one full refresh, after which the loop is closed again)."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, DeltaConfig, Domain, Engine, total_agents
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.core.halo import dirs_for
from repro.core.reshard import reshard_state
from repro.launch.mesh import make_abm_mesh

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 220
pos = rng.uniform(0.5, [31.5, 15.5], size=(n, 2)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, size=(n,)).astype(np.int32)}

def assert_closed_loop(state, mesh_shape):
    # neighbor pairing along axis 0: device (i, 0) vs (i+1, 0)
    refs = state.refs
    for i in range(mesh_shape[0] - 1):
        for field in refs["xp_out"]:
            a = np.asarray(refs["xp_out"][field])[i, 0]
            b = np.asarray(refs["xm_in"][field])[i + 1, 0]
            np.testing.assert_array_equal(a, b, err_msg=f"xp@{i} {field}")
            c = np.asarray(refs["xm_out"][field])[i + 1, 0]
            d = np.asarray(refs["xp_in"][field])[i, 0]
            np.testing.assert_array_equal(c, d, err_msg=f"xm@{i} {field}")

cfg = DeltaConfig(enabled=True, qdtype=jnp.int16, refresh_interval=6)
geom = Domain(cell_size=2.0, interior=(8, 8), mesh_shape=(2, 1), cap=16)
eng = Engine(geom=geom, behavior=beh, delta_cfg=cfg, dt=0.1)
state = eng.init_state(pos, attrs, seed=0)
step = eng.make_sharded_step(make_abm_mesh((2, 1)))

# arbitrary mixes of full refreshes and quantized-delta steps (seeded)
sched_rng = np.random.default_rng(7)
schedule = [True] + list(sched_rng.random(11) < 0.3)
for full in schedule:
    state = step(state, full_halo=bool(full))
    assert_closed_loop(state, (2, 1))
assert total_agents(state) == n

# mid-run re-shard: refs are reset; first step must be a full refresh,
# after which the closed loop holds on the NEW mesh
eng2, state2 = reshard_state(eng, state, (1, 2))
assert eng2.geom.mesh_shape == (1, 2)
step2 = eng2.make_sharded_step(make_abm_mesh((1, 2)))
state2 = step2(state2, full_halo=True)
refs = state2.refs
for field in refs["yp_out"]:
    np.testing.assert_array_equal(
        np.asarray(refs["yp_out"][field])[0, 0],
        np.asarray(refs["ym_in"][field])[0, 1], err_msg=field)
for full in [False, False, True, False]:
    state2 = step2(state2, full_halo=full)
    for field in refs["yp_out"]:
        np.testing.assert_array_equal(
            np.asarray(state2.refs["yp_out"][field])[0, 0],
            np.asarray(state2.refs["ym_in"][field])[0, 1], err_msg=field)
assert total_agents(state2) == n
print("OK")
""")
    assert "OK" in out


# ---------------------------------------------------------------------------
# 3-D facade + spheroid smoke (local)
# ---------------------------------------------------------------------------

def test_tumor_spheroid_grows_and_conserves():
    from repro.sims import tumor_spheroid

    state, metrics = tumor_spheroid.run(n_agents=40, steps=10)
    counts = [c for c, _ in metrics["series"]]
    assert counts[-1] > 40                     # nutrient-gated growth
    assert int(state.dropped.sum()) == 0
    assert state.soa.attrs[POS].shape[-1] == 3
    nut = np.asarray(state.soa.attrs["nutrient"]).ravel()
    v = np.asarray(state.soa.valid).ravel()
    assert (nut[v] >= 0).all() and (nut[v] <= 1).all()


def test_facade_3d_mesh_and_composed_stack():
    """A 3-D Domain through the Simulation facade: dict geometry with a
    3-axis interior, composed behavior list, scheduled reducer."""
    from repro.core import operations

    sim = Simulation(dict(interior=(3, 3, 3), cap=24),
                     [mech_behavior()], dt=0.1)
    assert sim.geom.ndim == 3 and sim.mesh is None
    pos, attrs = mech_inputs(sim.geom, n=60, seed=5)
    sim.init(pos, attrs, seed=0)
    sim.every(1, operations.agent_count, name="n")
    sim.run(4)
    assert sim.series["n"] == [60] * 4
    assert sim.iteration == 4
