"""Resilience stack tests: runtime health guards (core.guards),
deterministic fault injection (distributed.chaos), checkpoint hardening
(distributed.checkpoint), and supervised rollback recovery
(launch.supervise).

The recovery tests assert the headline guarantee: a fault injected at an
arbitrary step is detected by a guard, the supervised run completes by
rolling back to the last verified checkpoint, and the final state is
bit-exact with an uninterrupted run resumed from that same checkpoint.
Sharded variants (equal and rcb ownership, device loss) run in
subprocesses because XLA placeholder devices must be configured before
jax initializes.
"""

import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import textwrap
import zlib

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.core import (  # noqa: E402
    AgentSchema,
    Behavior,
    GuardConfig,
    HealthError,
    Simulation,
    health_counts,
)
from repro.core.behaviors import (  # noqa: E402
    displacement_update,
    soft_repulsion_adhesion,
)
from repro.core.guards import (  # noqa: E402
    GUARD_GID_DUP,
    GUARD_NAN,
    as_guard_config,
)
from repro.distributed import checkpoint as ckpt_lib  # noqa: E402
from repro.distributed.chaos import (  # noqa: E402
    ChaosError,
    Fault,
    FaultPlan,
)
from repro.launch.supervise import Supervised, Supervisor  # noqa: E402
from repro.sims.common import make_sim  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 4, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def _behavior():
    schema = AgentSchema.create({"diameter": ((), jnp.float32),
                                 "ctype": ((), jnp.int32)})
    return Behavior(
        schema=schema, pair_fn=soft_repulsion_adhesion,
        pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
        radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                            "same_type_only": 1.0, "max_step": 0.5})


def _init_data(n=300, seed=0):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.5, 31.5, size=(n, 2)).astype(np.float32)
    attrs = {"diameter": np.full((n,), 1.0, np.float32),
             "ctype": rng.integers(0, 2, size=(n,)).astype(np.int32)}
    return pos, attrs


def _make_guarded(tmp_path=None, guards="error", **kw):
    sim = make_sim(_behavior(), interior=(16, 16), cap=24, dt=0.5,
                   guards=guards, **kw)
    pos, attrs = _init_data()
    sim.init(pos, attrs)
    return sim


def _state_key(state):
    """Canonical (positions, gids) of live agents, gid-sorted — the
    bit-exactness currency."""
    v = np.asarray(state.soa.valid).ravel()
    nd = np.asarray(state.soa.attrs["pos"]).shape[-1]
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, nd)[v]
    gr = np.asarray(state.soa.attrs["gid_rank"]).ravel()[v]
    gc = np.asarray(state.soa.attrs["gid_count"]).ravel()[v]
    o = np.lexsort((gc, gr))
    return p[o], gr[o], gc[o]


def _poke_nan(sim, count=1):
    soa = sim.state.soa
    p = np.asarray(soa.attrs["pos"]).copy()
    v = np.asarray(soa.valid)
    for idx in np.argwhere(v)[:count]:
        p[tuple(idx)] = np.nan
    sim.state = dataclasses.replace(
        sim.state,
        soa=soa.replace(attrs={**soa.attrs, "pos": jnp.asarray(p)}))


# ---------------------------------------------------------------------------
# Guard config + guard trips (local)
# ---------------------------------------------------------------------------

def test_guard_config_validation():
    with pytest.raises(ValueError):
        GuardConfig(policy="loud")
    assert not GuardConfig().enabled
    assert GuardConfig(policy="warn").enabled
    assert as_guard_config(None) == GuardConfig()
    assert as_guard_config("error").policy == "error"
    with pytest.raises(TypeError):
        as_guard_config(42)


def test_healthy_run_no_trips_local():
    sim = _make_guarded()
    sim.run(20)
    assert health_counts(sim.state).tolist() == [0, 0, 0, 0, 0]
    assert sim.n_agents() == 300


def test_nan_guard_raises_under_error_policy():
    sim = _make_guarded()
    sim.run(3)
    _poke_nan(sim)
    with pytest.raises(HealthError) as ei:
        sim.run(2)
    assert "nan_inf" in str(ei.value)
    assert ei.value.report.new[GUARD_NAN] > 0


def test_nan_guard_warns_under_warn_policy():
    sim = _make_guarded(guards="warn")
    sim.run(3)
    _poke_nan(sim)
    with pytest.warns(UserWarning, match="nan_inf"):
        sim.run(2)
    assert health_counts(sim.state)[GUARD_NAN] > 0


def test_guards_off_by_default_sees_nothing():
    sim = _make_guarded(guards=None)
    _poke_nan(sim)
    sim.run(2)  # no raise, no warning machinery
    assert health_counts(sim.state).tolist() == [0, 0, 0, 0, 0]


def test_gid_duplicate_guard():
    sim = _make_guarded()
    sim.run(2)
    soa = sim.state.soa
    v = np.asarray(soa.valid)
    gr = np.asarray(soa.attrs["gid_rank"]).copy()
    gc = np.asarray(soa.attrs["gid_count"]).copy()
    a, b = np.argwhere(v)[:2]
    gr[tuple(b)] = gr[tuple(a)]
    gc[tuple(b)] = gc[tuple(a)]
    sim.state = dataclasses.replace(
        sim.state,
        soa=soa.replace(attrs={**soa.attrs,
                               "gid_rank": jnp.asarray(gr),
                               "gid_count": jnp.asarray(gc)}))
    with pytest.raises(HealthError) as ei:
        sim.run(1)
    assert ei.value.report.new[GUARD_GID_DUP] > 0


def test_engine_drive_checks_health():
    # guards surface through the low-level driver too, not only the facade
    from repro.core.engine import Engine

    sim = _make_guarded()
    _poke_nan(sim)
    eng: Engine = sim.engine
    with pytest.raises(HealthError):
        eng.drive(sim.state, 2)


# ---------------------------------------------------------------------------
# Fault plans (chaos)
# ---------------------------------------------------------------------------

def test_fault_plan_fire_once_and_determinism():
    sim1 = _make_guarded(guards=None)
    sim2 = _make_guarded(guards=None)
    plan1 = FaultPlan((Fault(step=0, kind="nan_attrs", frac=0.1),), seed=7)
    plan2 = FaultPlan((Fault(step=0, kind="nan_attrs", frac=0.1),), seed=7)
    s1, fired1 = plan1.fire(sim1.engine, sim1.state, 0)
    s2, _ = plan2.fire(sim2.engine, sim2.state, 0)
    assert fired1
    np.testing.assert_array_equal(np.asarray(s1.soa.attrs["pos"]),
                                  np.asarray(s2.soa.attrs["pos"]))
    # fire-once: the same step never corrupts twice
    s1b, fired_again = plan1.fire(sim1.engine, s1, 0)
    assert not fired_again and s1b is s1
    assert plan1.next_step(after=0) is None


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        Fault(step=3, kind="meteor")
    with pytest.raises(ValueError):
        Fault(step=-1, kind="raise")
    plan = FaultPlan((Fault(step=4, kind="raise"),
                      Fault(step=9, kind="raise")), seed=0)
    assert plan.next_step(after=0) == 4
    assert plan.next_step(after=4) == 9


def test_raise_fault_fires_from_run():
    sim = _make_guarded(guards=None)
    plan = FaultPlan((Fault(step=5, kind="raise"),))
    with pytest.raises(ChaosError):
        sim.run(10, fault_plan=plan)
    assert sim.iteration == 5  # segment broke exactly at the fault step


# ---------------------------------------------------------------------------
# Checkpoint hardening
# ---------------------------------------------------------------------------

def test_async_checkpointer_reraises_background_error(tmp_path):
    blocker = tmp_path / "ckpts"
    blocker.write_text("not a directory")
    ck = ckpt_lib.AsyncCheckpointer(str(blocker))
    ck.save(1, {"x": np.arange(4)})
    with pytest.raises(FileExistsError):
        ck.wait()
    # the error is consumed: a later wait() is clean
    assert ck.wait() is None


def test_async_checkpointer_sweeps_stale_tmp(tmp_path):
    stale = tmp_path / ".tmp_step_0000000003_999999999"
    stale.mkdir(parents=True)
    (stale / "leaf_00000.npy").write_bytes(b"junk")
    live = tmp_path / f".tmp_step_0000000004_{os.getpid()}"
    live.mkdir()
    ck = ckpt_lib.AsyncCheckpointer(str(tmp_path))
    assert not stale.exists()
    assert live.exists()  # our own pid: a concurrent writer, left alone
    assert str(stale) in ck.swept


def test_latest_step_skips_manifestless_dir(tmp_path):
    ckpt_lib.save(str(tmp_path), 5, {"x": np.arange(3)})
    (tmp_path / "step_0000000009").mkdir()
    with pytest.warns(UserWarning, match="step_0000000009"):
        assert ckpt_lib.latest_step(str(tmp_path)) == 5


def test_restore_skips_checksum_corrupt_checkpoint(tmp_path):
    ckpt_lib.save(str(tmp_path), 5, {"x": np.arange(3)})
    ckpt_lib.save(str(tmp_path), 10, {"x": np.arange(3) + 10})
    # flip the newest checkpoint's payload without touching its manifest
    np.save(tmp_path / "step_0000000010" / "leaf_00000.npy",
            np.arange(3) + 99)
    with pytest.warns(UserWarning, match="step_0000000010"):
        step, flat, _ = ckpt_lib.restore(str(tmp_path))
    assert step == 5
    np.testing.assert_array_equal(flat["x"], np.arange(3))
    with pytest.raises(ckpt_lib.CheckpointCorrupt, match="checksum"):
        ckpt_lib.restore(str(tmp_path), step=10)


def test_restore_skips_torn_leaf(tmp_path):
    ckpt_lib.save(str(tmp_path), 5, {"x": np.arange(100)})
    ckpt_lib.save(str(tmp_path), 10, {"x": np.arange(100)})
    leaf = tmp_path / "step_0000000010" / "leaf_00000.npy"
    with open(leaf, "r+b") as fh:
        fh.truncate(leaf.stat().st_size // 2)
    with pytest.warns(UserWarning, match="step_0000000010"):
        step, _, _ = ckpt_lib.restore(str(tmp_path))
    assert step == 5


def test_restore_all_corrupt_raises(tmp_path):
    ckpt_lib.save(str(tmp_path), 5, {"x": np.arange(3)})
    (pathlib.Path(tmp_path) / "step_0000000005" / "manifest.json"
     ).write_text("{broken")
    with pytest.warns(UserWarning):
        with pytest.raises(FileNotFoundError, match="no usable"):
            ckpt_lib.restore(str(tmp_path))


def test_save_manifest_carries_crc32(tmp_path):
    ckpt_lib.save(str(tmp_path), 3, {"x": np.arange(7, dtype=np.int32)})
    man = json.loads(
        (tmp_path / "step_0000000003" / "manifest.json").read_text())
    leaf = man["leaves"][0]
    want = zlib.crc32(np.arange(7, dtype=np.int32).tobytes())
    assert leaf["crc32"] == want


# ---------------------------------------------------------------------------
# Supervised recovery (local)
# ---------------------------------------------------------------------------

def test_supervision_contract_gates_unguarded_runs(tmp_path):
    from repro.analysis import ContractError, check_supervision

    sim = _make_guarded(guards=None)
    with pytest.raises(ContractError, match="guard policy 'off'"):
        sim.run(10, supervised=str(tmp_path / "ck"))
    diags = check_supervision(sim.engine, Supervised(dir="x", keep=1))
    contracts = {(d.severity, d.contract) for d in diags}
    assert ("error", "supervised-recovery") in contracts
    warn_sim = _make_guarded(guards="warn")
    diags = check_supervision(warn_sim.engine, Supervised(dir="x", keep=1))
    severities = [d.severity for d in diags]
    assert severities.count("warning") == 2  # warn policy + keep < 2


def test_supervised_nan_recovery_bit_exact_local(tmp_path):
    ck = str(tmp_path / "ck")
    sim = _make_guarded()
    plan = FaultPlan((Fault(step=7, kind="nan_attrs", frac=0.1),), seed=42)
    sv = Supervisor(sim, Supervised(dir=ck, every=5, keep=9),
                    fault_plan=plan)
    sv.run(12)
    assert sim.iteration == 12
    rec = sv.events("recovered")
    assert len(rec) == 1 and rec[0]["rolled_back_to"] == 5
    assert rec[0]["error_type"] == "HealthError"
    assert sv.events("completed")

    ctl = Simulation.restore(ck, _behavior(), step=5, guards="error")
    ctl.run(12 - 5)
    for a, b in zip(_state_key(sim.state), _state_key(ctl.state)):
        np.testing.assert_array_equal(a, b)


def test_supervised_run_via_facade_kwarg(tmp_path):
    ck = str(tmp_path / "ck")
    sim = _make_guarded()
    plan = FaultPlan((Fault(step=4, kind="raise"),))
    sim.run(8, supervised=Supervised(dir=ck, every=4, keep=9),
            fault_plan=plan)
    assert sim.iteration == 8
    assert ckpt_lib.latest_step(ck) == 8


def test_supervised_torn_checkpoint_rolls_back_further(tmp_path):
    ck = str(tmp_path / "ck")
    sim = _make_guarded()
    # tear the checkpoint written at step 10, then fail at 12: recovery
    # must skip the torn newest checkpoint and roll back to step 5
    plan = FaultPlan((Fault(step=10, kind="torn_checkpoint"),
                      Fault(step=12, kind="raise")))
    sv = Supervisor(sim, Supervised(dir=ck, every=5, keep=9),
                    fault_plan=plan)
    with pytest.warns(UserWarning, match="skipping corrupt checkpoint"):
        sv.run(15)
    assert sim.iteration == 15
    assert sv.events("torn_checkpoint")
    rec = sv.events("recovered")
    assert len(rec) == 1 and rec[0]["rolled_back_to"] == 5


def test_supervised_retry_exhaustion(tmp_path):
    ck = str(tmp_path / "ck")
    sim = _make_guarded()
    # distinct steps inside one chunk: every replay from the step-5
    # checkpoint trips a fresh fault until retries run out
    plan = FaultPlan((Fault(step=6, kind="raise"),
                      Fault(step=7, kind="raise"),
                      Fault(step=8, kind="raise")))
    sv = Supervisor(sim, Supervised(dir=ck, every=5, keep=9,
                                    max_retries=2), fault_plan=plan)
    with pytest.raises(ChaosError):
        sv.run(12)
    assert sv.events("giving_up")
    assert len(sv.events("recovered")) == 2


# ---------------------------------------------------------------------------
# Supervised recovery (sharded; subprocesses)
# ---------------------------------------------------------------------------

SHARDED_COMMON = """
import numpy as np, jax.numpy as jnp
from repro.core import AgentSchema, Behavior, Simulation
from repro.core.behaviors import soft_repulsion_adhesion, displacement_update
from repro.distributed.chaos import Fault, FaultPlan
from repro.launch.supervise import Supervised, Supervisor
from repro.sims.common import make_sim

schema = AgentSchema.create({"diameter": ((), jnp.float32),
                             "ctype": ((), jnp.int32)})
beh = Behavior(schema=schema, pair_fn=soft_repulsion_adhesion,
               pair_attrs=("diameter", "ctype"), update_fn=displacement_update,
               radius=2.0, params={"repulsion": 2.0, "adhesion": 0.4,
                                   "same_type_only": 1.0, "max_step": 0.5})
rng = np.random.default_rng(0)
n = 300
pos = rng.uniform(0.5, 31.5, size=(n, 2)).astype(np.float32)
attrs = {"diameter": np.full((n,), 1.0, np.float32),
         "ctype": rng.integers(0, 2, size=(n,)).astype(np.int32)}

def state_key(state):
    v = np.asarray(state.soa.valid).ravel()
    p = np.asarray(state.soa.attrs["pos"]).reshape(-1, 2)[v]
    gr = np.asarray(state.soa.attrs["gid_rank"]).ravel()[v]
    gc = np.asarray(state.soa.attrs["gid_count"]).ravel()[v]
    o = np.lexsort((gc, gr))
    return p[o], gr[o], gc[o]

def check_bitexact(sim, ck, rb, steps_after, n_devices=None):
    ctl = Simulation.restore(ck, beh, step=rb, n_devices=n_devices,
                             guards="error")
    ctl.run(steps_after)
    for a, b in zip(state_key(sim.state), state_key(ctl.state)):
        np.testing.assert_array_equal(a, b)
"""


def test_sharded_halo_fault_recovery_bit_exact(tmp_path):
    ck = str(tmp_path / "ck")
    out = run_sub(SHARDED_COMMON + f"""
sim = make_sim(beh, interior=(8, 16), mesh_shape=(2, 1), cap=24, dt=0.5,
               guards="error")
sim.init(pos, attrs)
plan = FaultPlan((Fault(step=6, kind="halo_slab", axis=0),), seed=3)
sv = Supervisor(sim, Supervised(dir={ck!r}, every=4, keep=9),
                fault_plan=plan)
sv.run(10)
assert sim.iteration == 10, sim.iteration
rec = sv.events("recovered")
assert len(rec) == 1 and rec[0]["rolled_back_to"] == 4, rec
assert rec[0]["error_type"] == "HealthError", rec
check_bitexact(sim, {ck!r}, 4, 6)
print("OK sharded halo-fault recovery")
""", devices=2)
    assert "OK sharded halo-fault recovery" in out


def test_overlapped_halo_fault_still_caught_before_boundary_pass(tmp_path):
    """Guard re-placement regression for the overlapped sweep: with the
    aura exchange hidden behind the interior pass (``overlap="on"``), a
    corrupted boundary receive (chaos ``halo_slab`` fault) must still be
    caught by the ``nan_inf`` guard *before* the boundary pass consumes
    the received ring — i.e. detection fires at the same step as on the
    sequential path, the recorded error is a HealthError (a guard trip,
    not NaN silently spreading through the boundary-face accumulators
    into positions), and supervised rollback recovery stays bit-exact."""
    ck = str(tmp_path / "ck")
    out = run_sub(SHARDED_COMMON + f"""
sim = make_sim(beh, interior=(8, 16), mesh_shape=(2, 1), cap=24, dt=0.5,
               guards="error", overlap="on")
sim.init(pos, attrs)
plan = FaultPlan((Fault(step=6, kind="halo_slab", axis=0),), seed=3)
sv = Supervisor(sim, Supervised(dir={ck!r}, every=4, keep=9),
                fault_plan=plan)
sv.run(10)
assert sim.iteration == 10, sim.iteration
rec = sv.events("recovered")
# caught at the fault step: rollback target is the checkpoint just
# below step 6, not some later step reached on corrupted state
assert len(rec) == 1 and rec[0]["rolled_back_to"] == 4, rec
assert rec[0]["error_type"] == "HealthError", rec
check_bitexact(sim, {ck!r}, 4, 6)
p = np.asarray(sim.state.soa.attrs["pos"])
assert np.isfinite(p[np.asarray(sim.state.soa.valid)]).all()
print("OK overlapped halo-fault recovery")
""", devices=2)
    assert "OK overlapped halo-fault recovery" in out


def test_sharded_device_loss_degrades_and_recovers(tmp_path):
    ck = str(tmp_path / "ck")
    out = run_sub(SHARDED_COMMON + f"""
sim = make_sim(beh, interior=(8, 8), mesh_shape=(2, 2), cap=24, dt=0.5,
               guards="error")
sim.init(pos, attrs)
n0 = sim.n_agents()
plan = FaultPlan((Fault(step=6, kind="device_loss", survivors=2),))
sv = Supervisor(sim, Supervised(dir={ck!r}, every=4, keep=9),
                fault_plan=plan)
sv.run(10)
assert sim.iteration == 10, sim.iteration
assert sim.engine.geom.n_devices == 2, sim.engine.geom.mesh_shape
assert sim.n_agents() == n0, (sim.n_agents(), n0)
rec = sv.events("recovered")
assert len(rec) == 1 and rec[0]["devices"] == 2, rec
assert rec[0]["rolled_back_to"] == 4, rec
import repro.core.guards as guards_mod
assert guards_mod.health_counts(sim.state).tolist() == [0, 0, 0, 0, 0]
check_bitexact(sim, {ck!r}, 4, 6, n_devices=2)
print("OK device-loss recovery")
""", devices=4)
    assert "OK device-loss recovery" in out


def test_sharded_rcb_ownership_inherited_through_recovery(tmp_path):
    ck = str(tmp_path / "ck")
    out = run_sub(SHARDED_COMMON + f"""
from repro.core import Partition
part = Partition(cuts=((0, 6, 16), (0, 9, 16)))
sim = make_sim(beh, partition=part, cap=64, dt=0.5, guards="error")
# skewed density (3/4 of agents in one corner cluster): every RCB re-plan
# along the recovery path cuts genuinely unevenly, so the inherited
# ownership mode never normalizes back to an equal split
pick = rng.random(n) < 0.75
pos = np.where(pick[:, None],
               rng.normal((7.0, 7.0), 3.0, (n, 2)),
               rng.normal((25.0, 25.0), 3.0, (n, 2)))
pos = np.clip(pos, 0.5, 31.5).astype(np.float32)
sim.init(pos, attrs)
assert sim.engine.geom.uneven
n0 = sim.n_agents()
plan = FaultPlan((Fault(step=5, kind="nan_attrs", frac=0.08),
                  Fault(step=9, kind="device_loss", survivors=2)), seed=11)
sv = Supervisor(sim, Supervised(dir={ck!r}, every=4, keep=9),
                fault_plan=plan)
sv.run(12)
assert sim.iteration == 12, sim.iteration
# the degraded restore inherited rcb ownership from the checkpoint
assert sim.engine.geom.uneven, sim.engine.geom
assert sim.engine.geom.n_devices == 2, sim.engine.geom.mesh_shape
assert sim.n_agents() == n0, (sim.n_agents(), n0)
recs = sv.events("recovered")
assert len(recs) == 2, recs
check_bitexact(sim, {ck!r}, recs[-1]["rolled_back_to"],
               12 - recs[-1]["rolled_back_to"], n_devices=2)
print("OK rcb recovery")
""", devices=4)
    assert "OK rcb recovery" in out


def test_sharded_healthy_guarded_run_no_false_positives(tmp_path):
    out = run_sub(SHARDED_COMMON + """
from repro.core import DeltaConfig
sim = make_sim(beh, interior=(8, 8), mesh_shape=(2, 2), cap=24, dt=0.5,
               delta=DeltaConfig(enabled=True, refresh_interval=4),
               guards="error")
sim.init(pos, attrs)
sim.run(16)
import repro.core.guards as guards_mod
assert guards_mod.health_counts(sim.state).tolist() == [0, 0, 0, 0, 0]
assert sim.n_agents() == n
print("OK healthy sharded guarded")
""", devices=4)
    assert "OK healthy sharded guarded" in out
